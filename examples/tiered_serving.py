"""Three-tier pool serving with a runtime quality dial — the deployment
story generalized past the paper's small/large pair.

Trains a tiny/small/large LM zoo, one router on the (tiny, large) quality
gap, and serves the same request stream through a ``ContinuousPoolEngine``
twice over:

  1. a ``CascadePolicy`` whose two gates come from ONE calibration-frontier
     sweep at a drop budget, and
  2. a ``QualityTargetPolicy`` swept across targets at serve time — the
     paper's "desired quality level" dial with no retraining and no
     recalibration: each query goes to the cheapest tier whose calibrated
     score->quality map clears the target.

Run: PYTHONPATH=src python examples/tiered_serving.py
"""
import dataclasses

import numpy as np

from repro.core.experiment import (build_experiment, pool_policy,
                                   train_pool_router)
from repro.models import build_model
from repro.serving import ContinuousEngine, ContinuousPoolEngine

TIERS3 = ("tiny", "small", "large")


def main():
    exp = build_experiment(seed=1, n_train_queries=300, n_test_queries=150,
                           n_samples=3, steps_scale=0.2, tiers=TIERS3)
    router_out = train_pool_router(exp, TIERS3, epochs=2)
    ds = exp.datasets["test"]

    # one engine per tier, cheapest -> priciest; the paged layout selects
    # the continuous-batching path (params are unchanged)
    engines = []
    for t in TIERS3:
        lm = exp.lms[t]
        bundle = build_model(dataclasses.replace(lm.cfg,
                                                 cache_layout="paged"))
        engines.append((t, ContinuousEngine(bundle, lm.params,
                                            max_new_tokens=12, n_slots=8,
                                            max_seq=64)))

    def serve(policy):
        pool = ContinuousPoolEngine(policy, engines)
        pool.serve(ds.query[:64], ds.query_mask[:64])
        return pool.meter

    print("== cascade (one frontier sweep, 2% drop budget) ==")
    cascade = pool_policy(exp, router_out, TIERS3, kind="cascade",
                          max_drop_pct=2.0)
    print("  gates: " + ", ".join(f"{t:.3f}" for t in cascade.thresholds))
    meter = serve(cascade)
    for name, row in meter.summary().items():
        print(f"  {name:<6} {row['calls']:>4} calls {row['gen_tokens']:>5} tok")
    print(f"  cost advantage vs all-large: {meter.cost_advantage:.0%} calls, "
          f"{meter.token_cost_advantage:.0%} tokens")

    print("\n== quality-target dial (same pool, tuned at serve time) ==")
    qt = pool_policy(exp, router_out, TIERS3, kind="quality_target")
    q_lo = float(exp.qualities["tiny"]["val"].mean())
    q_hi = float(exp.qualities["large"]["val"].mean())
    hdr = " ".join(f"{t:>6}" for t in TIERS3)
    print(f"{'target':>8} {hdr} {'calls-adv':>10} {'tokens-adv':>11}")
    for target in np.linspace(q_lo, q_hi, 4):
        qt.set_target(float(target))
        meter = serve(qt)
        frac = " ".join(f"{c / meter.total_calls:>6.0%}"
                        for c in meter.calls)
        print(f"{target:8.3f} {frac} {meter.cost_advantage:>10.0%} "
              f"{meter.token_cost_advantage:>11.0%}")


if __name__ == "__main__":
    main()
