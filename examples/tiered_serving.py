"""Three-tier pool serving with a runtime quality dial — the deployment
story generalized past the paper's small/large pair.

Trains a tiny/small/large LM zoo, one router on the (tiny, large) quality
gap, and serves the same request stream through a ``ContinuousPoolEngine``
twice over:

  1. a ``CascadePolicy`` whose two gates come from ONE calibration-frontier
     sweep at a drop budget, and
  2. a ``QualityTargetPolicy`` swept across targets at serve time — the
     paper's "desired quality level" dial with no retraining and no
     recalibration: each query goes to the cheapest tier whose calibrated
     score->quality map clears the target.

It then turns on the pool's speculative step plane (``spec_gamma=2``: each
tier drafts on its next-cheaper sibling, the target verifies the chunk in
one launch) and re-serves the same stream — byte-identical responses at
temperature 0, with the pricier tiers running fewer launches than tokens
emitted.

Run: PYTHONPATH=src python examples/tiered_serving.py
"""
import dataclasses

import numpy as np

from repro.core.experiment import (build_experiment, pool_policy,
                                   train_pool_router)
from repro.models import build_model
from repro.serving import ContinuousEngine, ContinuousPoolEngine

TIERS3 = ("tiny", "small", "large")


def main():
    exp = build_experiment(seed=1, n_train_queries=300, n_test_queries=150,
                           n_samples=3, steps_scale=0.2, tiers=TIERS3)
    router_out = train_pool_router(exp, TIERS3, epochs=2)
    ds = exp.datasets["test"]

    # one engine per tier, cheapest -> priciest; the paged layout selects
    # the continuous-batching path (params are unchanged)
    def fresh_engines():
        engs = []
        for t in TIERS3:
            lm = exp.lms[t]
            bundle = build_model(dataclasses.replace(lm.cfg,
                                                     cache_layout="paged"))
            engs.append((t, ContinuousEngine(bundle, lm.params,
                                             max_new_tokens=12, n_slots=8,
                                             max_seq=64)))
        return engs

    engines = fresh_engines()

    def serve(policy):
        pool = ContinuousPoolEngine(policy, engines)
        pool.serve(ds.query[:64], ds.query_mask[:64])
        return pool.meter

    print("== cascade (one frontier sweep, 2% drop budget) ==")
    cascade = pool_policy(exp, router_out, TIERS3, kind="cascade",
                          max_drop_pct=2.0)
    print("  gates: " + ", ".join(f"{t:.3f}" for t in cascade.thresholds))
    meter = serve(cascade)
    for name, row in meter.summary().items():
        print(f"  {name:<6} {row['calls']:>4} calls {row['gen_tokens']:>5} tok")
    print(f"  cost advantage vs all-large: {meter.cost_advantage:.0%} calls, "
          f"{meter.token_cost_advantage:.0%} tokens")

    print("\n== quality-target dial (same pool, tuned at serve time) ==")
    qt = pool_policy(exp, router_out, TIERS3, kind="quality_target")
    q_lo = float(exp.qualities["tiny"]["val"].mean())
    q_hi = float(exp.qualities["large"]["val"].mean())
    hdr = " ".join(f"{t:>6}" for t in TIERS3)
    print(f"{'target':>8} {hdr} {'calls-adv':>10} {'tokens-adv':>11}")
    for target in np.linspace(q_lo, q_hi, 4):
        qt.set_target(float(target))
        meter = serve(qt)
        frac = " ".join(f"{c / meter.total_calls:>6.0%}"
                        for c in meter.calls)
        print(f"{target:8.3f} {frac} {meter.cost_advantage:>10.0%} "
              f"{meter.token_cost_advantage:>11.0%}")

    print("\n== speculative step plane (spec_gamma=2, same stream) ==")
    # fresh engines per pool: attach_draft installs draft state on the
    # target engines, and the baseline must stay truly non-speculative
    results = {}
    for gamma in (0, 2):
        pool = ContinuousPoolEngine(cascade, fresh_engines(),
                                    spec_gamma=gamma)
        results[gamma] = pool.serve(ds.query[:64], ds.query_mask[:64])
        if gamma:
            for _, t in pool.plan.pairs:
                st = pool.engines[t].stats
                if not st.decode_tokens:
                    continue
                steps_per = (st.decode_steps + st.verify_steps) \
                    / st.decode_tokens
                print(f"  {TIERS3[t]:<6} {st.spec_rounds:>4} rounds "
                      f"{st.acceptance_rate:>5.0%} accepted "
                      f"{steps_per:>5.2f} target steps/token")
    exact = bool(np.array_equal(results[0].responses, results[2].responses)
                 and np.array_equal(results[0].lengths, results[2].lengths))
    print(f"  greedy-exact vs non-speculative pool: {exact}")
    assert exact, "speculation changed a temperature-0 response"


if __name__ == "__main__":
    main()
