"""Quickstart: the paper's pipeline end-to-end in ~2 minutes on CPU.

1. Train a small and a large LM on the synthetic instruction suite.
2. Sample responses, measure quality, build y_trans(t*) labels (§3.3).
3. Train the router, calibrate a threshold for <=2% drop (§4.5).
4. Serve a batch of queries through the hybrid engine and report the
   realised cost advantage (§2.3).

Run: PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import HybridRouter, calibrate_threshold, evaluate_threshold
from repro.core.experiment import build_experiment, train_pair_routers
from repro.serving import Engine, HybridEngine


def main():
    print("== building experiment (training S/L pair + sampling) ==")
    exp = build_experiment(seed=0, n_train_queries=400, n_test_queries=250,
                           n_samples=4, steps_scale=0.3,
                           tiers=("small", "large"))
    for t in ("small", "large"):
        print(f"  {t}: mean test quality "
              f"{exp.qualities[t]['test'].mean():+.3f}")

    print("== training r_trans router ==")
    routers = train_pair_routers(exp, "small", "large", kinds=("trans",),
                                 epochs=3)
    r = routers["trans"]
    print(f"  t* = {r['t_star']:.3f}")

    qs_v, ql_v = exp.qualities["small"]["val"], exp.qualities["large"]["val"]
    cal = calibrate_threshold(r["scores"]["val"], qs_v, ql_v, max_drop_pct=2.0)
    print(f"  calibrated threshold {cal.threshold:.3f} -> expect "
          f"{cal.expected_cost_advantage:.0%} cost advantage at "
          f"{cal.expected_drop_pct:.2f}% drop")

    ev = evaluate_threshold(cal.threshold, r["scores"]["test"],
                            exp.qualities["small"]["test"],
                            exp.qualities["large"]["test"])
    print(f"  test: {ev['cost_advantage']:.0%} cost advantage at "
          f"{ev['drop_pct']:.2f}% drop")

    print("== hybrid serving ==")
    router = HybridRouter(r["params"], r["rcfg"], cal.threshold)
    small = Engine(exp.lms["small"].bundle, exp.lms["small"].params,
                   max_new_tokens=12)
    large = Engine(exp.lms["large"].bundle, exp.lms["large"].params,
                   max_new_tokens=12)
    hybrid = HybridEngine(router, small, large)
    ds = exp.datasets["test"]
    for i in range(0, 192, 64):   # three batches of requests
        hybrid.serve(ds.query[i:i + 64], ds.query_mask[i:i + 64])
    print(f"  served {hybrid.meter.to_small + hybrid.meter.to_large} queries, "
          f"cost advantage {hybrid.meter.cost_advantage:.0%} "
          f"({hybrid.meter.to_small} -> small, "
          f"{hybrid.meter.to_large} -> large)")


if __name__ == "__main__":
    main()
