"""Architecture-zoo demo: every assigned architecture (reduced variant) runs
a forward pass, a train step, and a short generation through the SAME public
API — showing the framework's composable model definition.

Run: PYTHONPATH=src python examples/arch_zoo.py [--arch gemma3-4b]
"""
import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.common import softmax_xent
from repro.models.frontends import make_batch
from repro.serving.generate import build_generate_fn
from repro.training.optim import AdamWConfig, adamw_update, init_opt_state


def demo(arch: str):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    t0 = time.time()
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(jax.random.PRNGKey(1), cfg, 2, 16)
    logits, aux = m.forward(params, batch)
    ocfg = AdamWConfig(lr=1e-3, total_steps=10)
    opt = init_opt_state(params, ocfg)

    def loss_fn(p):
        lg, ax = m.forward(p, batch)
        return softmax_xent(lg, batch["labels"], batch["loss_mask"]) + 0.01 * ax

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, _ = adamw_update(params, grads, opt, ocfg)

    gen = build_generate_fn(m, 8, temperature=0.7)
    inf = {k: v for k, v in batch.items() if k not in ("labels", "loss_mask")}
    toks, lens = gen(params, inf, jax.random.PRNGKey(2))
    print(f"{arch:24s} [{cfg.family:6s}] loss={float(loss):6.2f} "
          f"gen={toks.shape} ({time.time() - t0:.1f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    args = ap.parse_args()
    for arch in ([args.arch] if args.arch else ARCH_IDS):
        demo(arch)


if __name__ == "__main__":
    main()
