"""Hybrid serving at different quality targets — the deployment story.

Serves the same request stream at several routing thresholds, showing the
dynamic quality/cost dial the paper advertises (tuned at test time, no
retraining). Also prints the per-engine serve stats.

Run: PYTHONPATH=src python examples/hybrid_serving.py
"""

from repro.core import HybridRouter, threshold_for_cost_advantage, mixture_quality, perf_drop_pct
from repro.core.experiment import build_experiment, train_pair_routers
from repro.serving import Engine, HybridEngine


def main():
    exp = build_experiment(seed=1, n_train_queries=400, n_test_queries=250,
                           n_samples=4, steps_scale=0.3,
                           tiers=("small", "large"))
    routers = train_pair_routers(exp, "small", "large", kinds=("trans",),
                                 epochs=3)
    r = routers["trans"]
    qs, ql = exp.qualities["small"]["test"], exp.qualities["large"]["test"]
    scores = r["scores"]["test"]
    ds = exp.datasets["test"]

    small = Engine(exp.lms["small"].bundle, exp.lms["small"].params,
                   max_new_tokens=12)
    large = Engine(exp.lms["large"].bundle, exp.lms["large"].params,
                   max_new_tokens=12)

    print(f"{'target':>8} {'achieved':>9} {'drop%':>7}")
    for target in (0.1, 0.2, 0.4, 0.6):
        thr = threshold_for_cost_advantage(scores, target)
        router = HybridRouter(r["params"], r["rcfg"], thr)
        hy = HybridEngine(router, small, large)
        hy.serve(ds.query[:128], ds.query_mask[:128])
        qmix, _ = mixture_quality(scores, thr, qs, ql)
        drop = perf_drop_pct(qmix, float(ql.mean()))
        print(f"{target:8.0%} {hy.meter.cost_advantage:9.0%} {drop:7.2f}")

    print(f"\nsmall engine: {small.stats.requests} reqs, "
          f"{small.stats.gen_tokens} tokens, {small.stats.wall_s:.1f}s")
    print(f"large engine: {large.stats.requests} reqs, "
          f"{large.stats.gen_tokens} tokens, {large.stats.wall_s:.1f}s")


if __name__ == "__main__":
    main()
